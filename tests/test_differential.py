"""Cross-backend differential fuzzer for the UVM replay stack.

Every registered :class:`~repro.uvm.replay_core.ReplayBackend` claims the
same timing model; this suite *derives* the pairwise guarantee instead of
hand-writing one test per backend pair.  For each generated (trace,
config, prefetcher) cell, the cell is replayed through **every** backend
whose ``can_replay`` accepts it, and all replays are compared pairwise:
integer counters exactly, cycles/pcie_bytes to 1e-9 relative.  A backend
registered tomorrow is covered by construction — it only has to show up
in ``available_backends()``.

Coverage is deliberately skewed toward the paper's hard regimes:

* tree cells under oversubscription (escalation counts rising and falling
  through LRU churn — the regime the dense count arrays must track),
* learned cells whose predictions ride through the ``repro.uvm.predcache``
  atomic store (the ``learned-cached`` variant, plus ``learned-tf``:
  the Transformer-family stand-in cached under its own
  ``model_family`` key — the model-family axis fuzzes across every
  backend pair by construction),
* tight-MSHR fault storms and ragged tiny traces,
* serving-traffic traces (``repro.offload.serve_trace``): the
  PagedKVStore-derived trace source replays through the same guarantee,
* multi-tenant interleaved traces (``repro.traces.interleave``) under
  shared capacity AND hard per-tenant quotas with a spill pool: the
  per-tenant hit/access counters and the tenant-masked victim selection
  are part of the pairwise guarantee (``tenant_pages`` is a fuzz axis),
* every eviction policy (lru/random/hotcold): the policy is a first-class
  fuzz axis, so every (backend pair × policy) combination is covered by
  construction — a seeded deterministic sweep exercises all policies even
  without hypothesis.

The legacy backend accepts everything, and the numpy/pallas backends must
accept every generated cell here (spans are small), so each example
compares at least three implementations; the suite fails loudly if a
backend silently stops accepting the fuzzed families (vacuous-pass
guard).  The deterministic seeded sweep below always runs; the
hypothesis-driven fuzzers widen it when hypothesis is installed (CI
installs it — see ``scripts/ci_check.sh``).
"""
import numpy as np
import pytest

from repro.traces.trace import ROOT_PAGES, Trace, make_records
from repro.uvm import UVMConfig
from repro.uvm.eviction import EVICTION_POLICIES
from repro.uvm.golden import make_prefetcher
from repro.uvm.replay_core import (ReplayRequest, available_backends,
                                   get_backend)

INT_FIELDS = ("n_accesses", "hits", "late", "faults", "prefetch_issued",
              "prefetch_used", "pages_migrated", "pages_evicted")
FLOAT_FIELDS = ("cycles", "pcie_bytes")

#: every fuzzed cell must be accepted by at least these backends — a
#: regression that silently shrinks a backend's eligibility would
#: otherwise turn the differential guarantee vacuous
REQUIRED_BACKENDS = {"legacy", "numpy", "pallas"}

PREFETCHER_NAMES = ("none", "block", "tree", "learned", "learned-cached",
                    "learned-tf", "oracle")


def _mk_trace(pages):
    pages = np.asarray(pages, dtype=np.int64)
    recs = make_records(len(pages))
    recs["page"] = pages
    return Trace("fuzz", recs, {}, {}, len(pages) * 100)


def _assert_pairwise_equal(stats_by_backend, context):
    names = sorted(stats_by_backend)
    ref_name = names[0]
    ref = stats_by_backend[ref_name]
    for name in names[1:]:
        got = stats_by_backend[name]
        for f in INT_FIELDS:
            assert getattr(got, f) == getattr(ref, f), (
                f"{context}: {name} vs {ref_name}: {f} "
                f"{getattr(got, f)} != {getattr(ref, f)}")
        for f in FLOAT_FIELDS:
            assert getattr(got, f) == pytest.approx(
                getattr(ref, f), rel=1e-9, abs=1e-9), (
                f"{context}: {name} vs {ref_name}: {f} "
                f"{getattr(got, f)} != {getattr(ref, f)}")
        # multi-tenant cells: per-tenant counters are part of the
        # guarantee too (None == None on single-tenant cells)
        for f in ("tenant_hits", "tenant_accesses"):
            g, r = getattr(got, f), getattr(ref, f)
            assert (g is None) == (r is None) and (
                g is None or tuple(map(int, g)) == tuple(map(int, r))), (
                f"{context}: {name} vs {ref_name}: {f} {g} != {r}")


def _replay_trace_everywhere(trace, pf_name, cap, mshr, eviction="lru",
                             step_bounds=None, tenant_pages=None):
    """Replay one (trace, config, prefetcher) cell through every accepting
    backend; returns {backend_name: stats}.

    With ``step_bounds`` the clock path is part of the guarantee: every
    required backend must still accept the request (pallas captures the
    clocks in-kernel), report a clock per window, and agree bitwise."""
    config = UVMConfig(device_pages=cap, mshr_entries=mshr,
                       eviction=eviction, tenant_pages=tenant_pages)
    stats_by_backend = {}
    for name in available_backends():
        backend = get_backend(name)
        # a fresh prefetcher per backend: replay consumes its state
        request = ReplayRequest(trace, make_prefetcher(pf_name, trace,
                                                       config), config,
                                step_bounds=step_bounds)
        if not backend.can_replay(request):
            continue
        stats = backend.replay([request])[0]
        assert stats.backend == name
        assert stats.eviction == eviction
        stats_by_backend[name] = stats
    missing = REQUIRED_BACKENDS - set(stats_by_backend)
    assert not missing, (
        f"backends {sorted(missing)} declined a fuzzed "
        f"({pf_name}, cap={cap}, eviction={eviction}, "
        f"bounds={step_bounds is not None}) cell — the differential "
        "guarantee would pass vacuously")
    if step_bounds is not None:
        names = sorted(stats_by_backend)
        ref = stats_by_backend[names[0]].step_clocks
        assert ref is not None and len(ref) == len(step_bounds), (
            f"{names[0]} returned no per-window clocks — the clock-path "
            "fuzz would pass vacuously")
        for name in names[1:]:
            clocks = stats_by_backend[name].step_clocks
            assert clocks is not None, f"{name} dropped step_clocks"
            assert np.array_equal(np.asarray(clocks), np.asarray(ref)), (
                f"{name} vs {names[0]}: step_clocks diverge "
                f"({pf_name}, cap={cap}, eviction={eviction})")
    return stats_by_backend


def _replay_everywhere(pages, pf_name, cap, mshr, eviction="lru",
                       step_bounds=None):
    return _replay_trace_everywhere(_mk_trace(pages), pf_name, cap, mshr,
                                    eviction, step_bounds=step_bounds)


def _draw_bounds(rng, n):
    """A valid ``step_bounds`` vector for an ``n``-access trace: a
    non-decreasing cut sequence over [0, n] — repeats (empty windows) and
    early cutoffs (bounds ending before the trace does) are both legal
    and deliberately common."""
    k = int(rng.integers(1, min(n, 48) + 1))
    return np.sort(rng.integers(0, n + 1, size=k)).astype(np.int64)


def _random_pages(rng):
    kind = rng.integers(0, 3)
    if kind == 0:
        # arbitrary small traces (ragged lengths, repeats, tiny sets)
        return rng.integers(0, 600, size=int(rng.integers(1, 160)))
    if kind == 1:
        # dense cyclic sweeps: oversubscription caps make these churn
        return np.tile(np.arange(int(rng.integers(64, 320))),
                       int(rng.integers(1, 5)))
    # strided sweeps crossing many basic blocks (block/tree escalation)
    return np.arange(0, int(rng.integers(256, 2048)),
                     int(rng.integers(1, 9)))


def _churn_pages(rng):
    """Permuted two-region sweeps: tree node counts rise and fall
    continuously (migrate/evict/re-migrate) under a tight cap."""
    n_churn = 2 * ROOT_PAGES
    perm = rng.permutation(n_churn)
    return np.concatenate([perm + (0 if k % 2 == 0 else 4096)
                           for k in range(4)])


# ---------------------------------------------------------------------------
# deterministic seeded sweep — always runs, even without hypothesis
# ---------------------------------------------------------------------------

def _seeded_cells():
    rng = np.random.default_rng(20260728)
    cells = []
    # every prefetcher family over random traces / caps / MSHR depths;
    # the cap index shifts by one per repetition of the name tuple so
    # each prefetcher sees a different capacity — including a real one —
    # in each of its three policy-rotated appearances
    for i, pf_name in enumerate(PREFETCHER_NAMES * 3):
        rep = i // len(PREFETCHER_NAMES)
        cells.append((f"seed{i}", _random_pages(rng), pf_name,
                      [None, 48, 200][(i + rep) % 3], [4, 16, 64][i % 3],
                      EVICTION_POLICIES[(i // 3) % 3]))
    # every (prefetcher, policy) pair under a guaranteed-thrashing cap —
    # (backend pair x policy) coverage by construction, hypothesis or not
    for j, pf_name in enumerate(PREFETCHER_NAMES):
        for policy in EVICTION_POLICIES:
            cells.append((f"pol-{policy}-{pf_name}", _random_pages(rng),
                          pf_name, [48, 200][j % 2], 16, policy))
    # tree-churn oversubscription cells (the ISSUE-called-out regime),
    # per policy: victim order diverges first in this regime
    for i, (cap, policy) in enumerate([(700, "lru"), (1100, "lru"),
                                       (None, "lru"), (700, "random"),
                                       (700, "hotcold")]):
        cells.append((f"churn{i}-{policy}", _churn_pages(rng), "tree",
                      cap, 16, policy))
    return cells


@pytest.mark.parametrize("cell", _seeded_cells(), ids=lambda c: c[0])
def test_differential_seeded_cells(cell):
    """Seeded random cells agree across every registered backend pair."""
    name, pages, pf_name, cap, mshr, eviction = cell
    stats = _replay_everywhere(pages, pf_name, cap, mshr, eviction)
    _assert_pairwise_equal(stats,
                           f"[{name}: {pf_name} cap={cap} mshr={mshr} "
                           f"eviction={eviction} n={len(pages)}]")


def test_step_bounds_eligibility_is_not_vacuous():
    """Every required backend accepts a bounds-carrying cell — if one
    silently started declining them (as pallas did before the in-kernel
    step clocks), the clock-path fuzzers would shrink to the host
    backends and pass vacuously."""
    rng = np.random.default_rng(3)
    pages = _random_pages(rng)
    trace = _mk_trace(pages)
    config = UVMConfig(device_pages=48, mshr_entries=16)
    bounds = _draw_bounds(rng, len(pages))
    for name in sorted(REQUIRED_BACKENDS):
        req = ReplayRequest(trace, make_prefetcher("none", trace, config),
                            config, step_bounds=bounds)
        assert get_backend(name).can_replay(req), name


def _seeded_clock_cells():
    rng = np.random.default_rng(20260807)
    cells = []
    for i, pf_name in enumerate(PREFETCHER_NAMES):
        pages = _random_pages(rng)
        cells.append((f"clk{i}-{pf_name}", pages, pf_name,
                      [None, 48, 200][i % 3], EVICTION_POLICIES[i % 3],
                      _draw_bounds(rng, len(pages))))
    return cells


@pytest.mark.parametrize("cell", _seeded_clock_cells(), ids=lambda c: c[0])
def test_differential_seeded_step_clocks(cell):
    """Seeded bounds-carrying cells: counters AND per-window clocks agree
    across every backend pair (the pallas clocks come from the kernel)."""
    name, pages, pf_name, cap, eviction, bounds = cell
    stats = _replay_everywhere(pages, pf_name, cap, 16, eviction,
                               step_bounds=bounds)
    _assert_pairwise_equal(stats,
                           f"[{name}: {pf_name} cap={cap} "
                           f"eviction={eviction} windows={len(bounds)}]")


def _serve_cells():
    """Serve-trace cells: the PagedKVStore-derived trace source replays
    bit-equal across all backends too (the ISSUE 6 acceptance bar).  Caps
    are chosen against the serve working set (~n_requests x
    blocks_per_seq unique pages) so both free-running and thrashing
    regimes are covered."""
    cells = []
    for bench, pf_name, cap, eviction in (
            ("ServeDecode", "none", None, "lru"),
            ("ServeDecode", "block", 120, "lru"),
            ("ServeDecode", "tree", 120, "hotcold"),
            ("ServeBursty", "none", 100, "random"),
            ("ServeBursty", "learned", 120, "lru"),
            ("ServeTenantMix", "block", 150, "lru")):
        cells.append((f"{bench}-{pf_name}-{cap}-{eviction}",
                      bench, pf_name, cap, eviction))
    return cells


@pytest.mark.parametrize("cell", _serve_cells(), ids=lambda c: c[0])
def test_differential_serve_traces(cell):
    """Serving-traffic traces (repro.offload.serve_trace) agree across
    every registered backend pair, like the GPU-model benchmarks."""
    from repro.offload.serve_trace import build_serve_trace

    name, bench, pf_name, cap, eviction = cell
    trace = build_serve_trace(bench, scale=0.2, seed=0)
    stats = _replay_trace_everywhere(trace, pf_name, cap, 16, eviction)
    _assert_pairwise_equal(stats, f"[serve {name} n={len(trace)}]")


# ---------------------------------------------------------------------------
# multi-tenant cells: tenancy (boundary-derived) + quota/spill arithmetic
# ---------------------------------------------------------------------------

#: tenant 1's region base for synthetic mt traces — far above every page
#: _random_pages can draw (< 2048), with room to spare
MT_BOUNDARY = 16 * ROOT_PAGES


def _mk_mt_trace(pages0, pages1):
    """Two fuzzed page streams as one interleaved multi-tenant trace:
    tenant 1 rebased above ``MT_BOUNDARY``, clock-proportional merge
    (same key arithmetic as ``repro.traces.interleave``)."""
    pages0 = np.asarray(pages0, dtype=np.int64)
    pages1 = np.asarray(pages1, dtype=np.int64) + MT_BOUNDARY
    na, nb = len(pages0), len(pages1)
    keys = np.concatenate([np.arange(1, na + 1, dtype=np.int64) * nb,
                           np.arange(1, nb + 1, dtype=np.int64) * na])
    order = np.argsort(keys, kind="stable")
    pages = np.concatenate([pages0, pages1])[order]
    recs = make_records(len(pages))
    recs["page"] = pages
    return Trace("fuzz-mt", recs, {}, {}, len(pages) * 100,
                 meta={"mt": {"benches": ["A", "B"], "tenants": 2,
                              "boundary": int(MT_BOUNDARY)}})


#: (q0, q1) quota splits fuzzed against a 240-page device: generous,
#: zero-spill, and asymmetric-with-spill
MT_SPLITS = (None, (80, 80), (100, 100), (140, 20))


def _mt_cells():
    rng = np.random.default_rng(20260808)
    cells = []
    for i, pf_name in enumerate(PREFETCHER_NAMES):
        for j, policy in enumerate(EVICTION_POLICIES):
            tp = MT_SPLITS[(i + j) % len(MT_SPLITS)]
            cap = 240 if tp else [None, 150][(i + j) % 2]
            cells.append((f"mt-{pf_name}-{policy}", _random_pages(rng),
                          _random_pages(rng), pf_name, cap, policy, tp))
    return cells


@pytest.mark.parametrize("cell", _mt_cells(), ids=lambda c: c[0])
def test_differential_multitenant_cells(cell):
    """Seeded multi-tenant cells — every (prefetcher, policy) pair across
    shared and quota splits — agree across every backend pair, per-tenant
    counters included."""
    name, p0, p1, pf_name, cap, policy, tp = cell
    stats = _replay_trace_everywhere(_mk_mt_trace(p0, p1), pf_name, cap,
                                     16, policy, tenant_pages=tp)
    for backend, st in stats.items():
        assert st.tenant_hits is not None, backend
        assert sum(st.tenant_accesses) == st.n_accesses, backend
        assert sum(st.tenant_hits) == st.hits, backend
    _assert_pairwise_equal(stats, f"[{name} cap={cap} quotas={tp}]")


def test_differential_multitenant_step_clocks():
    """A quota-split mt cell with drawn step bounds: the in-kernel clock
    path and the tenancy plane compose — counters, per-tenant counters,
    and per-window clocks all agree bitwise."""
    rng = np.random.default_rng(11)
    trace = _mk_mt_trace(_random_pages(rng), _random_pages(rng))
    bounds = _draw_bounds(rng, len(trace.accesses))
    stats = _replay_trace_everywhere(trace, "tree", 240, 16, "hotcold",
                                     step_bounds=bounds,
                                     tenant_pages=(100, 100))
    _assert_pairwise_equal(stats, f"[mt clocks windows={len(bounds)}]")


def test_differential_learned_cached_matches_plain():
    """Learned cells whose predictions round-trip the predcache store
    agree across all backends AND with the direct-array learned cell on
    every backend (the cache must be replay-invisible everywhere)."""
    rng = np.random.default_rng(7)
    for cap, eviction in ((None, "lru"), (48, "lru"), (48, "random"),
                          (48, "hotcold")):
        pages = rng.integers(0, 500, size=120)
        cached = _replay_everywhere(pages, "learned-cached", cap, 16,
                                    eviction)
        plain = _replay_everywhere(pages, "learned", cap, 16, eviction)
        _assert_pairwise_equal(cached,
                               f"[learned-cached cap={cap} ev={eviction}]")
        merged = dict(plain)
        merged.update({f"cached-{k}": v for k, v in cached.items()})
        _assert_pairwise_equal(merged,
                               f"[learned vs cached cap={cap} "
                               f"ev={eviction}]")


# ---------------------------------------------------------------------------
# hypothesis fuzzers (skipped when hypothesis is absent; CI installs it)
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st_

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - degraded environment
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:

    _pages = st_.one_of(
        st_.lists(st_.integers(0, 600), min_size=1, max_size=160),
        st_.builds(lambda n, reps: np.tile(np.arange(n, dtype=np.int64),
                                           reps).tolist(),
                   st_.integers(64, 320), st_.integers(1, 4)),
        st_.builds(lambda stop, step: np.arange(0, stop, step,
                                                dtype=np.int64).tolist(),
                   st_.integers(256, 2048), st_.integers(1, 9)),
    )

    _cell = st_.tuples(
        _pages,
        st_.sampled_from(PREFETCHER_NAMES),
        st_.sampled_from([None, 48, 200]),       # device capacity (pages)
        st_.sampled_from([4, 16, 64]),           # MSHR entries
        st_.sampled_from(EVICTION_POLICIES),     # eviction policy
    )

    @settings(max_examples=25, deadline=None)
    @given(_cell)
    def test_differential_random_cells(cell):
        """Random (trace, config, prefetcher, eviction policy) cells
        agree across every registered backend pair."""
        pages, pf_name, cap, mshr, eviction = cell
        stats = _replay_everywhere(pages, pf_name, cap, mshr, eviction)
        _assert_pairwise_equal(stats,
                               f"[{pf_name} cap={cap} mshr={mshr} "
                               f"eviction={eviction} n={len(pages)}]")

    _clock_cell = st_.tuples(
        _pages,
        st_.sampled_from(PREFETCHER_NAMES),
        st_.sampled_from([None, 48, 200]),       # device capacity (pages)
        st_.sampled_from(EVICTION_POLICIES),     # eviction policy
        st_.integers(0, 2 ** 32 - 1),            # step_bounds draw seed
    )

    @settings(max_examples=15, deadline=None)
    @given(_clock_cell)
    def test_differential_step_clock_cells(cell):
        """Random cells with drawn ``step_bounds``: every backend pair is
        fuzzed on the clock path — counters and per-window clocks must
        agree bitwise, and all required backends must keep accepting
        bounds requests (vacuity guard inside the helper)."""
        pages, pf_name, cap, eviction, bseed = cell
        bounds = _draw_bounds(np.random.default_rng(bseed), len(pages))
        stats = _replay_everywhere(pages, pf_name, cap, 16, eviction,
                                   step_bounds=bounds)
        _assert_pairwise_equal(stats,
                               f"[clocks {pf_name} cap={cap} "
                               f"eviction={eviction} "
                               f"windows={len(bounds)}]")

    _mt_cell = st_.tuples(
        _pages, _pages,                          # one stream per tenant
        st_.sampled_from(PREFETCHER_NAMES),
        st_.sampled_from(EVICTION_POLICIES),
        st_.sampled_from(MT_SPLITS),             # shared + quota splits
    )

    @settings(max_examples=12, deadline=None)
    @given(_mt_cell)
    def test_differential_multitenant_random(cell):
        """Random multi-tenant cells (two fuzzed streams, every
        prefetcher/policy, shared vs quota capacity): the tenancy plane
        agrees across every backend pair by construction."""
        pages0, pages1, pf_name, eviction, tp = cell
        cap = 240 if tp else 150
        stats = _replay_trace_everywhere(_mk_mt_trace(pages0, pages1),
                                         pf_name, cap, 16, eviction,
                                         tenant_pages=tp)
        _assert_pairwise_equal(stats,
                               f"[mt {pf_name} eviction={eviction} "
                               f"quotas={tp}]")

    @settings(max_examples=8, deadline=None)
    @given(st_.integers(0, 2 ** 32 - 1), st_.sampled_from([None, 700, 1100]),
           st_.sampled_from(EVICTION_POLICIES))
    def test_differential_tree_churn_oversubscription(seed, cap, eviction):
        """Tree cells on permuted two-region sweeps under
        oversubscription: node counts rise and fall continuously, the
        regime where per-level count state (and the policies' victim
        order) diverges first if any backend drifts."""
        pages = _churn_pages(np.random.default_rng(seed))
        stats = _replay_everywhere(pages, "tree", cap, 16, eviction)
        _assert_pairwise_equal(stats,
                               f"[tree-churn seed={seed} cap={cap} "
                               f"eviction={eviction}]")
