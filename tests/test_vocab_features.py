"""Vocabulary + feature-extraction properties."""
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="hypothesis not installed; property-based vocab tests skipped")
from hypothesis import given, settings, strategies as st

from repro.core import (DeltaVocab, cluster_trace, delta_convergence,
                        encode_features)
from repro.core.vocab import FEATURE_BUCKETS


def test_cluster_roundtrip(small_trace):
    ct = cluster_trace(small_trace, "sm")
    total = sum(len(p) for p in ct.pages)
    assert total <= len(small_trace)
    # global indices partition the trace
    all_idx = np.concatenate(ct.global_index)
    assert len(np.unique(all_idx)) == len(all_idx)
    # deltas consistent with pages
    for c, p in zip(ct.clusters, ct.pages):
        assert np.array_equal(c["dp"][1:], np.diff(p))


def test_convergence_bounds(small_trace):
    ct = cluster_trace(small_trace, "sm")
    c = delta_convergence(ct)
    assert 0.0 < c <= 1.0


def test_vocab_encode_decode(small_trace):
    ct = cluster_trace(small_trace, "sm")
    v = DeltaVocab.build(ct)
    deltas = np.concatenate([c["dp"][1:] for c in ct.clusters])[:500]
    enc = v.encode_fast(deltas)
    dec = v.decode(enc)
    known = enc != 0
    assert np.array_equal(dec[known], deltas[known])
    assert v.n_classes >= 2


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(-10**9, 10**9), min_size=1, max_size=200))
def test_encode_fast_matches_slow(deltas):
    import dataclasses
    arr = np.asarray(deltas, np.int64)
    vals = np.unique(arr[: max(len(arr) // 2, 1)])
    vocab = DeltaVocab(
        deltas=np.concatenate([[np.iinfo(np.int64).min], vals]),
        index={int(d): i + 1 for i, d in enumerate(vals)})
    assert np.array_equal(vocab.encode(arr), vocab.encode_fast(arr))


def test_feature_encoding_bounds(small_trace):
    ct = cluster_trace(small_trace, "sm")
    enc = encode_features(ct.clusters[0])
    from repro.core import FEATURE_NAMES
    for j, f in enumerate(FEATURE_NAMES):
        assert enc[:, j].min() >= 0
        assert enc[:, j].max() < FEATURE_BUCKETS[f]


def test_distance_vocab(small_trace):
    ct = cluster_trace(small_trace, "sm")
    v1 = DeltaVocab.build(ct, distance=1)
    v8 = DeltaVocab.build(ct, distance=8)
    # distance-8 deltas of a stride stream = 8x the stride: disjoint-ish
    assert v8.n_classes >= 2
    assert v1.n_classes >= 2
