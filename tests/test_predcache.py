"""Prediction cache: content-addressed keys, bit-identical round trips,
atomic concurrent writes, and the train-once hit path."""
import multiprocessing
import os

import numpy as np
import pytest

from repro.traces.trace import Trace, make_records
from repro.uvm import predcache


def _mk_trace(pages, name="synth", n_instructions=None):
    pages = np.asarray(pages, dtype=np.int64)
    recs = make_records(len(pages))
    recs["page"] = pages
    recs["sm"] = np.arange(len(pages)) % 4
    return Trace(name, recs, {}, {},
                 n_instructions if n_instructions is not None
                 else len(pages) * 100)


def test_store_load_bit_identical(tmp_path):
    cache = str(tmp_path)
    rng = np.random.default_rng(0)
    preds = rng.integers(-1, 1 << 40, size=10_000, dtype=np.int64)
    key = "deadbeef" * 3
    predcache.store(cache, key, preds)
    back = predcache.load(cache, key)
    assert back is not None
    assert back.dtype == preds.dtype
    np.testing.assert_array_equal(back, preds)
    assert not back.flags.writeable          # cached arrays are shared


def test_load_missing_is_none(tmp_path):
    assert predcache.load(str(tmp_path), "0" * 24) is None
    assert predcache.load(str(tmp_path / "nope"), "0" * 24) is None


def test_key_sensitivity():
    tr = _mk_trace(np.arange(500) % 37)
    base = dict(steps=100, distance=8, seed=0, min_prob=0.35)
    k0 = predcache.predictions_key(tr, **base)
    assert k0 == predcache.predictions_key(tr, **base)   # deterministic
    # every configuration axis moves the key
    for variant in (dict(base, steps=101), dict(base, distance=30),
                    dict(base, seed=1), dict(base, min_prob=0.5)):
        assert predcache.predictions_key(tr, **variant) != k0
    # trace content moves the key: different pages, and same pages with a
    # different instruction count
    other = _mk_trace((np.arange(500) % 37) + 1)
    assert predcache.predictions_key(other, **base) != k0
    longer = _mk_trace(np.arange(500) % 37, n_instructions=123)
    assert predcache.predictions_key(longer, **base) != k0


def test_key_is_content_addressed():
    """Two traces with identical records agree on the key regardless of
    how/where they were constructed (e.g. npz cache vs generator)."""
    a = _mk_trace(np.arange(300), name="a")
    b = _mk_trace(np.arange(300), name="b")
    assert (predcache.predictions_key(a, steps=10)
            == predcache.predictions_key(b, steps=10))


def _writer(cache_dir, key, fill, n_writes):
    arr = np.full(4096, fill, dtype=np.int64)
    for _ in range(n_writes):
        predcache.store(cache_dir, key, arr)


def test_concurrent_writers_never_corrupt(tmp_path):
    """N processes hammering the same key: readers must always observe a
    complete array from one writer (atomic rename), never a torn file."""
    cache = str(tmp_path)
    key = "c0ffee" * 4
    # spawn, not fork: the pytest process is multi-threaded (jax) by the
    # time this runs, and forking a threaded parent can deadlock
    ctx = multiprocessing.get_context("spawn")
    fills = [1, 2, 3, 4]
    procs = [ctx.Process(target=_writer, args=(cache, key, f, 40))
             for f in fills]
    for p in procs:
        p.start()
    seen = 0
    try:
        while any(p.is_alive() for p in procs):
            arr = predcache.load(cache, key)
            if arr is not None:
                assert arr.shape == (4096,)
                uniq = np.unique(arr)
                assert uniq.size == 1 and int(uniq[0]) in fills
                seen += 1
    finally:
        for p in procs:
            p.join(timeout=60)
    assert all(p.exitcode == 0 for p in procs)
    arr = predcache.load(cache, key)
    assert arr is not None and np.unique(arr).size == 1
    assert seen > 0                      # we really raced the writers
    # no tempfiles leaked behind the renames
    assert not [f for f in os.listdir(cache) if f.endswith(".tmp.npz")]


def test_get_or_train_hits_skip_training(tmp_path, monkeypatch):
    """A warm cache returns the stored array bit-identically without ever
    touching the predictor service."""
    from repro.core.service import PredictorService

    predcache.clear_memo()
    cache = str(tmp_path)
    tr = _mk_trace(np.arange(400) % 53)
    svc = PredictorService(steps=7, seed=3)
    fields = {f: getattr(svc, f) for f in predcache.SERVICE_KEY_FIELDS}
    key = predcache.predictions_key(tr, **fields)
    preds = np.arange(len(tr), dtype=np.int64) - 1
    predcache.store(cache, key, preds)

    def _boom(self, *a, **k):
        raise AssertionError("cache hit must not train")

    monkeypatch.setattr(PredictorService, "fit", _boom)
    got = predcache.get_or_train(tr, steps=7, seed=3, cache_dir=cache)
    np.testing.assert_array_equal(got, preds)
    # second call comes from the in-process memo (same array object)
    again = predcache.get_or_train(tr, steps=7, seed=3, cache_dir=cache)
    assert again is got
    predcache.clear_memo()


def test_get_or_train_respects_disable_env(tmp_path, monkeypatch):
    """REPRO_PREDCACHE=0 is the retrain-per-cell baseline: even a warm
    cache is ignored."""
    from repro.core.service import PredictorService

    predcache.clear_memo()
    cache = str(tmp_path)
    tr = _mk_trace(np.arange(200) % 31)
    svc = PredictorService(steps=5)
    fields = {f: getattr(svc, f) for f in predcache.SERVICE_KEY_FIELDS}
    predcache.store(cache, predcache.predictions_key(tr, **fields),
                    np.zeros(len(tr), dtype=np.int64))
    monkeypatch.setenv("REPRO_PREDCACHE", "0")
    calls = []
    monkeypatch.setattr(PredictorService, "fit",
                        lambda self, *a, **k: calls.append(1))
    monkeypatch.setattr(PredictorService, "predict_trace",
                        lambda self: np.ones(len(tr), dtype=np.int64))
    got = predcache.get_or_train(tr, steps=5, cache_dir=cache)
    assert calls == [1]
    assert int(got[0]) == 1              # trained, not the cached zeros


def test_stale_lock_does_not_deadlock(tmp_path, monkeypatch):
    """A dead trainer's leftover lockfile must not wedge waiters forever:
    legacy bare-pid locks read as TTL-less lease records and are stolen
    immediately."""
    from repro.core.service import PredictorService

    predcache.clear_memo()
    cache = str(tmp_path)
    tr = _mk_trace(np.arange(150) % 17)
    svc = PredictorService(steps=5)
    fields = {f: getattr(svc, f) for f in predcache.SERVICE_KEY_FIELDS}
    key = predcache.predictions_key(tr, **fields)
    os.makedirs(cache, exist_ok=True)
    # fake an abandoned lock with no result behind it
    with open(os.path.join(cache, f"preds_{key}.npz.lock"), "w") as f:
        f.write("99999")
    monkeypatch.setattr(PredictorService, "fit",
                        lambda self, *a, **k: None)
    monkeypatch.setattr(PredictorService, "predict_trace",
                        lambda self: np.full(len(tr), 7, dtype=np.int64))
    got = predcache.get_or_train(tr, steps=5, cache_dir=cache,
                                 lock_poll_s=0.01, lock_patience_s=0.05)
    assert int(got[0]) == 7
    predcache.clear_memo()


def test_dead_pid_lock_reclaimed_before_patience(tmp_path, monkeypatch):
    """Satellite: a SIGKILLed trainer's lock (fresh timestamp, dead pid)
    is reclaimed via the owner-pid liveness check — waiters do not serve
    the TTL/patience window."""
    import json
    import time

    from repro.core.service import PredictorService
    from repro.distributed import fault_tolerance as ft

    predcache.clear_memo()
    cache = str(tmp_path)
    tr = _mk_trace(np.arange(150) % 19)
    svc = PredictorService(steps=5)
    fields = {f: getattr(svc, f) for f in predcache.SERVICE_KEY_FIELDS}
    key = predcache.predictions_key(tr, **fields)
    os.makedirs(cache, exist_ok=True)
    doc = ft.lease_doc()
    doc["pid"] = 2 ** 22 + 11            # beyond any default pid_max
    assert not ft.pid_alive(doc["pid"])
    with open(os.path.join(cache, f"preds_{key}.npz.lock"), "w") as f:
        json.dump(doc, f)                # fresh ts: TTL alone won't expire

    monkeypatch.setattr(PredictorService, "fit",
                        lambda self, *a, **k: None)
    monkeypatch.setattr(PredictorService, "predict_trace",
                        lambda self: np.full(len(tr), 9, dtype=np.int64))
    t0 = time.monotonic()
    got = predcache.get_or_train(tr, steps=5, cache_dir=cache,
                                 lock_poll_s=0.25, lock_patience_s=120.0)
    waited = time.monotonic() - t0
    assert int(got[0]) == 9
    assert waited < 30.0                 # did not sit out the patience
    predcache.clear_memo()


def test_corrupt_entry_quarantined_and_retrained(tmp_path):
    """Checksummed entries: truncation and bit flips are detected on
    read, the entry is quarantined to .corrupt, and the key reads as a
    miss (retrain) instead of serving corrupt predictions."""
    cache = str(tmp_path)
    preds = np.arange(5000, dtype=np.int64)

    # truncation
    key_t = "feed" * 6
    path_t = predcache._path(cache, key_t)
    predcache.store(cache, key_t, preds)
    with open(path_t, "r+b") as f:
        f.truncate(os.path.getsize(path_t) // 2)
    with pytest.warns(RuntimeWarning, match="quarantining"):
        assert predcache.load(cache, key_t) is None
    assert os.path.exists(path_t + ".corrupt")
    assert not os.path.exists(path_t)

    # single bit flip in the embedded array bytes
    key_b = "beef" * 6
    path_b = predcache._path(cache, key_b)
    predcache.store(cache, key_b, preds)
    size = os.path.getsize(path_b)
    with open(path_b, "r+b") as f:
        f.seek(size // 2)
        b = f.read(1)
        f.seek(size // 2)
        f.write(bytes([b[0] ^ 0x10]))
    with pytest.warns(RuntimeWarning, match="quarantining"):
        assert predcache.load(cache, key_b) is None
    assert os.path.exists(path_b + ".corrupt")

    # a rewritten entry round-trips again
    predcache.store(cache, key_t, preds)
    np.testing.assert_array_equal(predcache.load(cache, key_t), preds)


def test_model_family_keys_never_cross_serve(tmp_path, monkeypatch):
    """Tentpole regression: the key carries the model identity
    (``model_family`` + resolved-config digest), so two predictor
    families on the same trace get distinct keys and can never serve
    each other's cached arrays — through the memo or the disk cache."""
    from repro.core.families import MODEL_FAMILIES
    from repro.core.service import PredictorService

    predcache.clear_memo()
    cache = str(tmp_path)
    tr = _mk_trace(np.arange(300) % 41)
    keys = {}
    for fam in MODEL_FAMILIES:
        svc = PredictorService(steps=5, model_family=fam)
        fields = {f: getattr(svc, f) for f in predcache.SERVICE_KEY_FIELDS}
        keys[fam] = predcache.predictions_key(tr, **fields)
    assert len(set(keys.values())) == len(MODEL_FAMILIES)

    # train both families with distinguishable outputs: a collision
    # would surface the wrong family's fill value
    fills = {"simplified": 1, "transformer": 2}
    monkeypatch.setattr(PredictorService, "fit",
                        lambda self, *a, **k: None)
    monkeypatch.setattr(
        PredictorService, "predict_trace",
        lambda self: np.full(len(tr), fills[self.model_family],
                             dtype=np.int64))
    simp = predcache.get_or_train(
        tr, steps=5, cache_dir=cache,
        service_kwargs={"model_family": "simplified"})
    tf = predcache.get_or_train(
        tr, steps=5, cache_dir=cache,
        service_kwargs={"model_family": "transformer"})
    assert int(simp[0]) == 1 and int(tf[0]) == 2

    # cold memo: both must come back family-correct from *disk*, and a
    # hit must not retrain
    predcache.clear_memo()
    monkeypatch.setattr(
        PredictorService, "fit",
        lambda self, *a, **k: (_ for _ in ()).throw(
            AssertionError("disk hit must not train")))
    for fam, want in fills.items():
        got = predcache.get_or_train(tr, steps=5, cache_dir=cache,
                                     service_kwargs={"model_family": fam})
        assert int(got[0]) == want
    predcache.clear_memo()


def test_trace_content_key_freezes_accesses():
    """Satellite: the content key is memoized on the trace, which is only
    sound if the hashed bytes cannot change afterwards — keying must
    freeze the access array so a later in-place mutation raises instead
    of silently reusing a stale fingerprint."""
    tr = _mk_trace(np.arange(200) % 23)
    assert tr.accesses.flags.writeable
    k0 = predcache.trace_content_key(tr)
    assert not tr.accesses.flags.writeable
    with pytest.raises(ValueError):
        tr.accesses["page"][0] = 12345
    # the memoized key stays honest: unchanged bytes, unchanged key
    assert predcache.trace_content_key(tr) == k0


def test_corrupt_holder_stolen_without_burning_patience(tmp_path,
                                                        monkeypatch):
    """Satellite: a lock holder that trained, stored a *corrupt* entry
    (injected via the ``pred.artifact`` fault plane), and died must not
    cost waiters the full ``lock_patience_s``: the checksummed probe
    observes the corruption, quarantines the entry, steals the
    still-live-looking foreign lease, and retrains immediately."""
    import json
    import time

    from repro.core.service import PredictorService
    from repro.uvm import faults

    predcache.clear_memo()
    cache = str(tmp_path / "cache")
    ledger = str(tmp_path / "ledger")
    tr = _mk_trace(np.arange(150) % 13)
    svc = PredictorService(steps=5)
    fields = {f: getattr(svc, f) for f in predcache.SERVICE_KEY_FIELDS}
    key = predcache.predictions_key(tr, **fields)
    path = predcache._path(cache, key)

    # the "holder": stores its result under a fault plan that truncates
    # the entry right after the atomic rename (bounded to one firing, so
    # the waiter's own retrain stores cleanly), then dies mid-lease
    plan = {"seed": 0, "ledger_dir": ledger, "specs": [
        {"site": "pred.artifact", "kind": "truncate", "prob": 1.0,
         "max_count": 1}]}
    monkeypatch.setenv(faults.FAULT_PLAN_ENV, json.dumps(plan))
    faults.reset()
    try:
        predcache.store(cache, key, np.zeros(len(tr), dtype=np.int64))
    finally:
        monkeypatch.delenv(faults.FAULT_PLAN_ENV)
        faults.reset()
    assert os.listdir(ledger)            # the corruption really fired
    # its lease looks *live*: foreign host (no pid probe possible) with a
    # fresh timestamp, so neither the dead-pid nor the TTL path steals it
    with open(path + ".lock", "w") as f:
        json.dump({"pid": 1, "host": "definitely-not-this-host",
                   "ts": time.time(), "role": "predcache-train"}, f)

    monkeypatch.setattr(PredictorService, "fit",
                        lambda self, *a, **k: None)
    monkeypatch.setattr(PredictorService, "predict_trace",
                        lambda self: np.full(len(tr), 5, dtype=np.int64))
    t0 = time.monotonic()
    with pytest.warns(RuntimeWarning, match="quarantining"):
        got = predcache.get_or_train(tr, steps=5, cache_dir=cache,
                                     lock_poll_s=0.25,
                                     lock_patience_s=60.0)
    waited = time.monotonic() - t0
    assert int(got[0]) == 5              # retrained, not the corrupt zeros
    assert waited < 15.0                 # did not wait out the lease
    assert os.path.exists(path + ".corrupt")
    np.testing.assert_array_equal(predcache.load(cache, key), got)
    predcache.clear_memo()
